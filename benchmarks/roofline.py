"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single|multi] [--md]

Terms (seconds, PER DEVICE per step — the dry-run HLO is the SPMD per-device
program; trip-count-corrected by benchmarks/hlo_analysis.py):

    compute    = flops / PEAK_FLOPS        (197 TFLOP/s bf16, TPU v5e)
    memory     = hbm_bytes / HBM_BW        (819 GB/s)
    collective = collective_bytes / ICI_BW (50 GB/s/link; bytes already
                                            per-device result-bytes)

MODEL_FLOPS = minimal algorithmic flops (6·N·D for LM train, 2·N·D serve,
attention + family-specific formulas below), divided by device count —
the "useful fraction" MODEL_FLOPS / HLO_FLOPS exposes remat/dispatch waste.
"""
from __future__ import annotations

import argparse
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link
VMEM_BYTES = 16 << 20    # on-chip vector memory / core (TPU v4/v5e class);
#                          the megakernel's tile budget derives from here
#                          (repro.kernels.mega_query.ops), never hardcoded

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

# ------------------------------------------------------- MODEL_FLOPS --------
LM = {
    "gemma-7b": dict(L=28, d=3072, H=16, Kv=16, Dh=256, ff=24576, V=256000,
                     active=None, window=None),
    "yi-6b": dict(L=32, d=4096, H=32, Kv=4, Dh=128, ff=11008, V=64000,
                  active=None, window=None),
    "qwen3-4b": dict(L=36, d=2560, H=32, Kv=8, Dh=128, ff=9728, V=151936,
                     active=None, window=None),
    "mixtral-8x7b": dict(L=32, d=4096, H=32, Kv=8, Dh=128, ff=14336, V=32000,
                         active=2, experts=8, window=4096),
    "llama4-maverick-400b-a17b": dict(L=48, d=5120, H=40, Kv=8, Dh=128,
                                      ff=8192, V=202048, active=2,  # top1+shared
                                      experts=128, window=8192),
}

SHAPES = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
          "decode_32k": (32768, 128), "long_500k": (524288, 1)}


def lm_params_active(c):
    attn = c["d"] * (c["H"] * c["Dh"] * 2 + c["Kv"] * c["Dh"] * 2)
    n_ff = c.get("active") or 1
    mlp = 3 * c["d"] * c["ff"] * n_ff
    per_layer = attn + mlp + 2 * c["d"]
    return c["L"] * per_layer + c["V"] * c["d"]


def lm_model_flops(arch, shape):
    c = LM[arch]
    S, B = SHAPES[shape]
    N = lm_params_active(c)
    ctx = min(S, c["window"]) if c["window"] else S
    if shape == "train_4k":
        T = S * B
        param_f = 6 * N * T
        attn_f = 3 * 2 * 2 * T * (S / 2) * c["H"] * c["Dh"]   # fwd+bwd(2x)
        return param_f + attn_f
    if shape == "prefill_32k":
        T = S * B
        return 2 * N * T + 2 * 2 * T * (ctx / 2) * c["H"] * c["Dh"]
    # decode: one token per sequence
    return 2 * N * B + 2 * 2 * B * ctx * c["H"] * c["Dh"]


def recsys_model_flops(arch, shape):
    mult = 3 if shape == "train_batch" else 1
    B = {"train_batch": 65536, "serve_p99": 512, "serve_bulk": 262144,
         "retrieval_cand": 1}[shape]
    if shape == "retrieval_cand":
        d = {"dlrm-mlperf": 128, "dien": 36, "bst": 32, "xdeepfm": 10}[arch]
        return 2 * 1_048_576 * d
    if arch == "dlrm-mlperf":
        bot = 2 * (13 * 512 + 512 * 256 + 256 * 128)
        inter = 2 * 27 * 27 * 128
        top = 2 * (479 * 1024 + 1024 * 1024 + 1024 * 512 + 512 * 256 + 256)
        return mult * B * (bot + inter + top)
    if arch == "dien":
        d2, g, T = 36, 108, 100
        gru = 2 * T * 3 * (d2 * g + g * g) * 2          # 2 GRU passes
        att = 2 * T * ((g + d2) * 80 + 80 * 40 + 40)
        top = 2 * ((g + 2 * d2) * 200 + 200 * 80 + 80)
        return mult * B * (gru + att + top)
    if arch == "bst":
        d, T = 32, 21
        attn = 2 * 2 * T * T * d + 2 * 4 * T * d * d
        ff = 2 * T * (d * 4 * d * 2)
        top = 2 * ((T * d + 8 * d) * 1024 + 1024 * 512 + 512 * 256 + 256)
        return mult * B * (attn + ff + top)
    if arch == "xdeepfm":
        m, D = 39, 10
        cin = 0
        hk = m
        for h in (200, 200, 200):
            cin += 2 * hk * m * D + 2 * hk * m * D * h
            hk = h
        deep = 2 * (m * D * 400 + 400 * 400 + 400)
        return mult * B * (cin + deep)
    raise KeyError(arch)


def gnn_model_flops(shape):
    d, rbf, n_int = 64, 300, 3
    cells = {"full_graph_sm": (3072, 10752, 1433, 16),
             "minibatch_lg": (169984, 168960, 602, 41),
             "ogb_products": (2449408, 61865984, 100, 47),
             "molecule": (4096, 8192, 0, 1)}
    N, E, d_in, n_out = cells[shape]
    per_int = 2 * E * (rbf * d + d * d) + E * d + 2 * N * d * d * 3
    embed = 2 * N * max(d_in, 1) * d
    head = 2 * N * (d * d // 2 + d // 2 * n_out)
    return 3 * (n_int * per_int + embed + head)   # train: fwd+bwd


def irli_model_flops(shape, n_dev=256):
    R, d, H, B_buckets = 32, 96, 1024, 20000
    if shape == "train_scorers":
        batch = 1 << 15
        return 3 * batch * R * 2 * (d * H + H * B_buckets)
    # serve: by DESIGN every corpus shard scores ALL queries against its own
    # slice (paper §5.3 — zero cross-node traffic until the merge), so the
    # scorer work is replicated x n_dev; multiply so the per-device division
    # in build() cancels for the replicated part.
    q, topc = 4096, 1024
    per_dev = q * (R * 2 * (d * H + H * B_buckets) + 2 * topc * d)
    return per_dev * n_dev


def model_flops(arch, shape, n_dev=256):
    # NOTE: useful_frac may exceed 1 for scatter/gather-dominated archs
    # (schnet): the analytic model bills per-edge elementwise message work
    # that lowers to non-dot HLO ops, which the HLO counter (dots/convs
    # only) does not see.
    if arch in LM:
        return lm_model_flops(arch, shape)
    if arch == "schnet":
        return gnn_model_flops(shape)
    if arch == "irli-deep1b":
        return irli_model_flops(shape, n_dev)
    return recsys_model_flops(arch, shape)


def kernel_roofline(name, seconds, flops, hbm_bytes):
    """Achieved-vs-peak for ONE measured kernel invocation — the live
    counterpart of build(): HLO-counted flops/bytes (hlo_analysis.analyze_hlo
    over the kernel's own compiled module) plus a wall-clock timing, instead
    of dry-run artifacts. Peaks are the TPU v5e chip numbers above, so on
    the CPU container ``frac_of_roofline`` is a cross-platform reference
    point rather than a local efficiency claim (benchmarks.
    bench_kernel_roofline records both and pushes them through the metrics
    registry)."""
    t_c = flops / PEAK_FLOPS
    t_m = hbm_bytes / HBM_BW
    return {
        "name": name, "seconds": seconds,
        "flops": flops, "hbm_bytes": hbm_bytes,
        "achieved_gflops": flops / seconds / 1e9 if seconds > 0 else 0.0,
        "achieved_gbps": hbm_bytes / seconds / 1e9 if seconds > 0 else 0.0,
        "peak_gbps": HBM_BW / 1e9,
        "bound": "compute" if t_c >= t_m else "memory",
        "frac_of_roofline": max(t_c, t_m) / seconds if seconds > 0 else 0.0,
    }


# ------------------------------------------------------------- the table ----
def build(mesh: str, use_corrected: bool = True):
    with open(os.path.join(ART, f"dryrun_{mesh}.json")) as f:
        d = json.load(f)
    n_dev = 512 if mesh == "multi" else 256
    rows = []
    for key, v in sorted(d.items()):
        arch, shape = key.split("/")
        if v["status"] == "skip":
            rows.append({"cell": key, "status": "skip", "why": v["reason"]})
            continue
        if v["status"] != "ok":
            rows.append({"cell": key, "status": "error"})
            continue
        c = v.get("corrected", {})
        flops = c.get("flops") or v.get("flops") or 0
        hbm = c.get("hbm_bytes") or v.get("bytes_accessed") or 0
        coll = c.get("collective_bytes", 0)
        t_c = flops / PEAK_FLOPS
        t_m = hbm / HBM_BW
        t_n = coll / ICI_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
                  key=lambda x: x[1])[0]
        mf = model_flops(arch, shape, n_dev) / n_dev
        rows.append({
            "cell": key, "status": "ok",
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "dominant": dom,
            "model_flops_per_dev": mf,
            "hlo_flops_per_dev": flops,
            "useful_frac": mf / flops if flops else 0.0,
            "roofline_frac": (mf / PEAK_FLOPS) / max(t_c, t_m, t_n)
            if max(t_c, t_m, t_n) > 0 else 0.0,
            "temp_gib": v.get("temp_size_in_bytes", 0) / 2**30,
            "collectives": c.get("collectives", {}),
        })
    return rows


def markdown(rows, mesh):
    out = [f"### Roofline — {mesh} pod mesh "
           f"({'2x16x16=512' if mesh == 'multi' else '16x16=256'} chips, "
           "TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)", "",
           "| cell | compute s | memory s | collective s | bound | useful "
           "(model/HLO) | roofline frac | temp GiB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skip":
            out.append(f"| {r['cell']} | — | — | — | SKIP | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['cell']} | ERROR |  |  |  |  |  |  |")
            continue
        out.append(
            f"| {r['cell']} | {r['compute_s']:.3g} | {r['memory_s']:.3g} | "
            f"{r['collective_s']:.3g} | {r['dominant']} | "
            f"{r['useful_frac']:.2f} | {r['roofline_frac']:.2f} | "
            f"{r['temp_gib']:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = build(args.mesh)
    with open(os.path.join(ART, f"roofline_{args.mesh}.json"), "w") as f:
        json.dump(rows, f, indent=1)
    if args.md:
        print(markdown(rows, args.mesh))
    else:
        for r in rows:
            if r["status"] == "ok":
                print(f"{r['cell']:42s} {r['dominant']:10s} "
                      f"cmp={r['compute_s']:.3g} mem={r['memory_s']:.3g} "
                      f"net={r['collective_s']:.3g} "
                      f"useful={r['useful_frac']:.2f} "
                      f"roof={r['roofline_frac']:.2f}")
            else:
                print(f"{r['cell']:42s} {r['status'].upper()}")


if __name__ == "__main__":
    main()
