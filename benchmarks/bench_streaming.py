"""Streaming mutable-index benchmark: sustained insert + query throughput,
recall vs fraction inserted (inserted items must be findable immediately),
and load-balance drift vs the paper's power-of-K claim (Thm. 2 — online
placement with live load counters should keep load_std near the fitted
value, NOT degrade toward random hashing).
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.index import IRLIIndex, IRLIConfig
from repro.core.search_api import SearchParams
from repro.data.synthetic import clustered_ann, _topk_l2
from repro.stream import MutableIRLIIndex

SP = SearchParams(m=8, tau=1, k=10)


def _load_std(load) -> float:
    return float(jnp.mean(jnp.std(load.astype(jnp.float32), axis=1)))


def run(csv=True):
    n_init, n_stream, d = 4000, 4000, 16
    data = clustered_ann(n_base=n_init + n_stream, n_queries=200, d=d,
                         n_clusters=100, seed=0)
    base, stream_vecs = data.base[:n_init], data.base[n_init:]
    gt = _topk_l2(base, base, 10, "angular")
    cfg = IRLIConfig(d=d, n_labels=n_init, n_buckets=128, n_reps=4,
                     d_hidden=64, K=8, rounds=2, epochs_per_round=3,
                     batch_size=512, lr=2e-3, seed=1)
    idx = IRLIIndex(cfg)
    idx.fit(base, gt, label_vecs=base)
    mut = MutableIRLIIndex(idx, base)
    std0 = _load_std(mut.snapshot.load)

    rows = [("streaming/load_std_fitted", 0.0, std0)]

    def qps(queries, repeats=3):
        mut.search(queries, SP).ids.block_until_ready()               # warmup
        t0 = time.perf_counter()
        for _ in range(repeats):
            mut.search(queries, SP).ids.block_until_ready()
        return repeats * queries.shape[0] / (time.perf_counter() - t0)

    rows.append(("streaming/query_qps_frozen", 0.0, qps(data.queries)))

    # sustained insert+query: stream in chunks, measure throughput + recall
    # of THIS chunk's items immediately after its insert
    chunk = 500
    t_ins = 0.0
    for frac_i, s in enumerate(range(0, n_stream, chunk)):
        batch = stream_vecs[s:s + chunk]
        t0 = time.perf_counter()
        ids = mut.insert(batch)
        t_ins += time.perf_counter() - t0
        got = np.asarray(mut.search(batch, SP).ids)
        rec = float(np.mean([ids[i] in got[i] for i in range(len(ids))]))
        frac = (s + len(batch)) / n_stream
        rows.append((f"streaming/recall_inserted@frac={frac:.2f}",
                     t_ins * 1e6, rec))
    rows.append(("streaming/insert_throughput_items_per_s", t_ins * 1e6,
                 n_stream / t_ins))
    rows.append(("streaming/query_qps_after_inserts", 0.0, qps(data.queries)))
    rows.append(("streaming/load_std_after_inserts", 0.0,
                 _load_std(mut.snapshot.load)))
    rows.append(("streaming/load_std_drift", 0.0,
                 _load_std(mut.snapshot.load) - std0))

    # delete 10% then compact; post-compaction QPS (smaller member matrix)
    mut.delete(np.arange(0, n_init, 10))
    t0 = time.perf_counter()
    mut.compact()
    rows.append(("streaming/compaction_us", (time.perf_counter() - t0) * 1e6,
                 _load_std(mut.snapshot.load)))
    rows.append(("streaming/query_qps_compacted", 0.0, qps(data.queries)))

    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived:.3f}")
    from benchmarks import trajectory
    trajectory.record("streaming", rows)
    return rows


if __name__ == "__main__":
    run()
