"""QueryPipeline dense vs compact: recall parity at matched candidate
budgets, latency, and the memory crossover that motivates routing all
serving through the compact path (ISSUE 2 / paper §5.3: never materialize
per-query full-corpus state).

For each corpus size: fit one IRLI index, serve the same queries through
QueryPipeline(mode="dense") and QueryPipeline(mode="compact", topC=budget),
and report end-to-end recall10@10 (against true neighbors), mean survivor
count, per-query latency, and the [Q, L] dense-table bytes the compact path
avoids. Recall parity: compact == dense wherever the candidate budget covers
the survivors.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import query as Q
from repro.core.index import IRLIIndex, IRLIConfig
from repro.core.search_api import SearchParams
from repro.data.synthetic import clustered_ann

N_QUERIES = 200


def _recall_of_ids(ids, gt):
    """End-to-end recall k@k from final top-k id lists (pad -1 never hits)."""
    ids, gt = np.asarray(ids), np.asarray(gt)
    k = min(ids.shape[1], gt.shape[1])
    return float(np.mean([
        len(set(r[r >= 0]) & set(g[:k])) / k for r, g in zip(ids, gt)]))


def run(csv=True):
    rows = []
    for L, B_ in ((2000, 64), (8000, 128)):
        data = clustered_ann(n_base=L, n_queries=N_QUERIES, d=16,
                             n_clusters=L // 20, seed=0)
        cfg = IRLIConfig(d=16, n_labels=L, n_buckets=B_, n_reps=4,
                         d_hidden=64, K=8, rounds=2, epochs_per_round=3,
                         batch_size=512, lr=2e-3, seed=1)
        idx = IRLIIndex(cfg)
        idx.fit(data.train_queries, data.train_gt, label_vecs=data.base)
        queries = jnp.asarray(data.queries)
        base = jnp.asarray(data.base)

        # topC=2048 >= the candidate width R·m·max_load at both corpus sizes
        # -> the matched-budget compact run must reproduce dense recall
        # exactly; topC=256 shows the truncated-budget tradeoff
        for mode, topC in (("dense", 2048), ("compact", 2048),
                           ("compact", 256)):
            sp = SearchParams(mode=mode, m=4, tau=1, k=10, topC=topC)
            res = idx.search(queries, base, sp)
            ids, n_cand = res.ids, res.n_candidates
            jnp.asarray(ids).block_until_ready()
            t0 = time.time()
            for _ in range(3):
                out = idx.search(queries, base, sp)
                out.ids.block_until_ready()
            us = (time.time() - t0) / (3 * N_QUERIES) * 1e6
            rec = _recall_of_ids(ids, data.gt)
            dense_bytes = 2 * N_QUERIES * L * 4     # count + sim tables
            tag = mode if mode == "dense" else f"{mode}_C={topC}"
            rows.append((
                f"compact_vs_dense/L={L}_{tag}", us,
                f"recall={rec:.3f};cand={float(n_cand.mean()):.0f};"
                f"dense_table_bytes={dense_bytes if mode == 'dense' else 0}"))

    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    from benchmarks import trajectory
    trajectory.record("compact_vs_dense", rows)
    return rows


if __name__ == "__main__":
    run()
