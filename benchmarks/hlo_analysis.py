"""HLO-text cost model with WHILE-LOOP TRIP-COUNT correction.

Why: ``compiled.cost_analysis()`` counts a while-loop body ONCE, but our
programs are scan-heavy (layers x microbatches x CE chunks), so raw XLA
numbers under-count FLOPs 30-200x. This module re-derives the three roofline
inputs from the compiled HLO text:

  flops             dot/conv: 2 * prod(result) * contraction, x trip counts
  hbm_bytes         HBM traffic model: every top-level (non-fused) op's
                    RESULT bytes, x trip counts. Each buffer is billed once
                    at its producer; fused interiors are free (VMEM).
  collective_bytes  result bytes of all-gather/all-reduce/reduce-scatter/
                    all-to-all/collective-permute, x trip counts, per kind.

Trip counts: scan loops compare the induction variable against a literal in
the loop CONDITION computation; we take the largest integer constant there.

Parsing notes (XLA CPU post-optimization dumps): every instruction is
``%name = TYPE opcode(operands), attrs``; operand types are NOT inline, so a
module-wide symbol table (name -> dims) resolves dot contraction sizes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "copy-start", "copy-done", "partition-id",
            "replica-id", "opt-barrier", "optimization-barrier"}


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_type_op(rhs: str):
    """rhs after '=': returns (type_str, opcode, rest). Handles tuple types."""
    s = rhs.lstrip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = s[:i + 1]
                    rest = s[i + 1:].lstrip()
                    break
        else:
            return s, "", ""
    else:
        m = re.match(r"[\w\[\],]+(\{[^}]*\})?\s*", s)
        if not m:
            return s, "", ""
        type_str = m.group(0)
        rest = s[m.end():]
    mo = re.match(r"([a-z][\w\-]*)\(", rest)
    op = mo.group(1) if mo else ""
    return type_str, op, rest


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    is_entry: bool = False
    is_fused: bool = False


def split_computations(txt: str):
    comps: dict[str, Computation] = {}
    symbols: dict[str, str] = {}     # instr name -> type string
    cur = None
    for raw in txt.splitlines():
        line = raw.strip()
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            mm = re.search(r"%([\w\.\-]+)", line)
            name = mm.group(1) if mm else f"anon{len(comps)}"
            cur = Computation(name=name, is_entry=line.startswith("ENTRY"))
            comps[name] = cur
            continue
        if line == "}":
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        nm = _NAME_RE.match(line)
        if not nm:
            continue
        rhs = line[line.index("=") + 1:]
        type_str, op, rest = _split_type_op(rhs)
        if not op:
            continue
        inst = Instr(nm.group(1), type_str, op, rest, line)
        cur.instrs.append(inst)
        symbols[inst.name] = type_str
    # mark fusion callees
    for c in comps.values():
        for inst in c.instrs:
            if inst.op == "fusion":
                m = _CALLS_RE.search(inst.line)
                if m and m.group(1) in comps:
                    comps[m.group(1)].is_fused = True
    return comps, symbols


def _dims_of(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _elems(type_str: str) -> int:
    n = 1
    for d in _dims_of(type_str):
        n *= d
    return n


def _dot_flops(inst: Instr, symbols: dict) -> int:
    result_elems = _elems(inst.type_str)
    ops = re.findall(r"%([\w\.\-]+)", inst.rest.split(")", 1)[0])
    if not ops:
        return 0
    lhs_dims = _dims_of(symbols.get(ops[0], ""))
    mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
    contraction = 1
    if mcd:
        for i in mcd.group(1).split(","):
            if i and int(i) < len(lhs_dims):
                contraction *= lhs_dims[int(i)]
    return 2 * result_elems * contraction


def _conv_flops(inst: Instr, symbols: dict) -> int:
    result_elems = _elems(inst.type_str)
    ops = re.findall(r"%([\w\.\-]+)", inst.rest.split(")", 1)[0])
    if len(ops) < 2:
        return 0
    k_dims = _dims_of(symbols.get(ops[1], ""))
    k_elems = 1
    for d in k_dims[:-1]:
        k_elems *= d
    return 2 * result_elems * max(k_elems, 1)


def trip_count(comps: dict, cond_name: str) -> int:
    c = comps.get(cond_name)
    if c is None:
        return 1
    best = 1
    for inst in c.instrs:
        for v in _CONST_RE.findall(inst.line):
            best = max(best, int(v))
    return best


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_count: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
        self.coll_count += other.coll_count * mult

    @property
    def collective_bytes(self):
        return sum(self.coll.values())


def comp_cost(comps, symbols, name, memo) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()   # cycle guard
    c = comps.get(name)
    if c is None:
        return memo[name]
    cost = Cost()
    for inst in c.instrs:
        op = inst.op
        if op in SKIP_OPS:
            continue
        if op == "while":
            w = _WHILE_RE.search(inst.line)
            if w:
                t = trip_count(comps, w.group(1))
                cost.add(comp_cost(comps, symbols, w.group(2), memo), t)
                cost.hbm_bytes += type_bytes(inst.type_str)  # carry in/out
            continue
        if op == "fusion":
            mm = _CALLS_RE.search(inst.line)
            if mm:
                inner = comp_cost(comps, symbols, mm.group(1), memo)
                cost.flops += inner.flops
                for k in COLLECTIVES:
                    cost.coll[k] += inner.coll[k]
                cost.coll_count += inner.coll_count
            cost.hbm_bytes += type_bytes(inst.type_str)
            continue
        if op in ("call", "async-start", "custom-call"):
            mm = _TO_APPLY_RE.search(inst.line) or _CALLS_RE.search(inst.line)
            if mm:
                cost.add(comp_cost(comps, symbols, mm.group(1), memo), 1.0)
            cost.hbm_bytes += type_bytes(inst.type_str)
            continue
        if op == "conditional":
            for mm in re.finditer(
                    r"(?:branch_computations=\{|true_computation=|"
                    r"false_computation=)%?([\w\.\-]+)", inst.line):
                cost.add(comp_cost(comps, symbols, mm.group(1), memo), 1.0)
            continue
        hit = next((k for k in COLLECTIVES if op.startswith(k)), None)
        if hit is not None:
            if op.endswith("-done"):
                continue
            b = type_bytes(inst.type_str)
            cost.coll[hit] += b
            cost.coll_count += 1
            cost.hbm_bytes += b
            continue
        if op == "dot":
            cost.flops += _dot_flops(inst, symbols)
            cost.hbm_bytes += type_bytes(inst.type_str)
            continue
        if op.startswith("convolution"):
            cost.flops += _conv_flops(inst, symbols)
            cost.hbm_bytes += type_bytes(inst.type_str)
            continue
        if op == "dynamic-update-slice":
            # in-place on TPU: bill only the update slice, not the buffer
            ops = re.findall(r"%([\w\.\-]+)", inst.rest.split(")", 1)[0])
            upd = symbols.get(ops[1], "") if len(ops) > 1 else ""
            cost.hbm_bytes += type_bytes(upd) or type_bytes(inst.type_str)
            continue
        if not c.is_fused:
            # top-level op boundary: bill the produced buffer once
            cost.hbm_bytes += type_bytes(inst.type_str)
    memo[name] = cost
    return cost


def analyze_hlo(txt: str) -> dict:
    comps, symbols = split_computations(txt)
    entry = next((n for n, c in comps.items() if c.is_entry), None)
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n].instrs))
    memo: dict = {}
    cost = comp_cost(comps, symbols, entry, memo)
    return {
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "collective_bytes": cost.collective_bytes,
        "collectives": dict(cost.coll),
        "collective_count": cost.coll_count,
        "n_computations": len(comps),
    }


if __name__ == "__main__":
    import json
    import sys
    print(json.dumps(analyze_hlo(open(sys.argv[1]).read()), indent=1))
