"""DEPRECATED shim — the HLO cost model moved to ``repro.analysis.hlo``.

The promoted module adds the donation auditor (input_output_alias vs
donate_argnums), per-kind collective profiling, and the trip-count fixes
(order-independent while attrs, compare-operand constants, an explicit
warning instead of a silent 1x undercount on dynamic bounds). This shim
re-exports the old names for out-of-tree callers.
"""
import warnings

from repro.analysis.hlo import (  # noqa: F401
    COLLECTIVES, SKIP_OPS, Computation, Cost, Instr, analyze_hlo, comp_cost,
    split_computations, trip_count, type_bytes)

warnings.warn(
    "benchmarks.hlo_analysis is deprecated; import repro.analysis.hlo",
    DeprecationWarning, stacklevel=2)
