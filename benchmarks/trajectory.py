"""Longitudinal perf trajectory — every bench run appends its headline
numbers to ``artifacts/TRAJECTORY.jsonl`` (one JSON row per metric, schema
below), building a queryable perf history across commits:

    {"name": "store/int8_rerank", "value": 1234.0, "unit": "us_per_call",
     "derived": 0.98, "bench": "store", "git_rev": "4c9f52b",
     "ts": 1754650000.0}

``record`` is called from every ``benchmarks/bench_*.py`` at the end of its
``run()`` (rows are the same ``(name, us_per_call, derived)`` tuples the
CSV prints, so the two outputs can never disagree); ``check`` compares each
metric's newest value against the MEDIAN of its prior recordings and fails
loudly on a >``REGRESSION_FACTOR``x latency regression — the guard
``benchmarks/run.py`` runs after every full sweep (opt out with
``--no-check``, e.g. on deliberately slower debug builds).

    PYTHONPATH=src python -m benchmarks.trajectory --check   # gate only
    PYTHONPATH=src python -m benchmarks.trajectory           # print history
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import time

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
DEFAULT_PATH = os.path.join(ART, "TRAJECTORY.jsonl")

#: latest > REGRESSION_FACTOR * median(prior) => regression (the issue's
#: ">20%" gate)
REGRESSION_FACTOR = 1.2

#: units where LARGER is WORSE (latency-like); other units are informational
#: and never gate
_LATENCY_UNITS = ("us_per_call", "us", "ms", "s", "seconds")

#: units where LARGER is BETTER (quality-like): the gate direction inverts —
#: the newest value regresses when it DROPS below median(prior)/factor. This
#: is how audited-recall rows (benchmarks/bench_online.py, unit "recall")
#: trip ``enforce`` exactly like a latency blowup would.
_QUALITY_UNITS = ("recall", "frac")

#: all gated larger-is-worse units: latency plus the static-analysis
#: peak-memory rows the audit CLI records (analysis_peak_bytes{contract=...},
#: unit "bytes") — a growing intermediate is a regression exactly like a
#: growing latency
_GATED_UNITS = _LATENCY_UNITS + ("bytes",)


def git_rev() -> str:
    """Short git revision of the working tree ('unknown' outside a repo)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else "unknown"
    except Exception:
        return "unknown"


def record(bench: str, rows, unit: str = "us_per_call",
           path: str | None = None, registry=None) -> list:
    """Append one JSONL row per ``(name, value, derived)`` bench tuple.

    ``derived`` is the bench's second column (recall, std, bytes ratio, ...)
    and rides along untyped. A ``registry`` (obs.MetricRegistry) mirrors
    each value as a ``bench_value{bench=...,name=...}`` gauge. Returns the
    written row dicts."""
    path = path or DEFAULT_PATH
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    rev, ts = git_rev(), time.time()
    written = []
    with open(path, "a", encoding="utf-8") as fh:
        for name, value, derived in rows:
            row = {"name": str(name), "value": float(value), "unit": unit,
                   "derived": (float(derived)
                               if isinstance(derived, (int, float))
                               else derived),
                   "bench": bench, "git_rev": rev, "ts": ts}
            fh.write(json.dumps(row) + "\n")
            written.append(row)
            if registry is not None:
                registry.gauge("bench_value",
                               {"bench": bench, "name": str(name)}
                               ).set(float(value))
    return written


def load(path: str | None = None) -> list:
    """All trajectory rows, oldest first. Malformed lines are skipped (a
    crashed writer must not poison the whole history)."""
    path = path or DEFAULT_PATH
    if not os.path.exists(path):
        return []
    rows = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(row, dict) and "name" in row and "value" in row:
                rows.append(row)
    return rows


def check(path: str | None = None,
          factor: float = REGRESSION_FACTOR) -> list:
    """Regression gate: for every gated-unit metric with >= 2 recordings,
    compare the NEWEST value against the median of all PRIOR values.
    Returns a list of human-readable failure strings (empty = pass).

    Direction follows the unit: latency-like/"bytes" rows regress when the
    newest value EXCEEDS factor * median(prior); quality rows ("recall",
    "frac" — larger is better) regress when it DROPS below
    median(prior) / factor. Median-of-priors (not just the previous run)
    keeps one historic noisy sample from either masking or faking a
    regression."""
    by_name: dict = {}
    for row in load(path):
        unit = row.get("unit")
        if unit in _GATED_UNITS and row["value"] > 0:
            by_name.setdefault(row["name"], ("worse", []))[1].append(
                row["value"])
        elif unit in _QUALITY_UNITS and row["value"] >= 0:
            # 0 is a legal (terrible) recall — it must still gate
            by_name.setdefault(row["name"], ("better", []))[1].append(
                row["value"])
    failures = []
    for name, (direction, vals) in sorted(by_name.items()):
        if len(vals) < 2:
            continue
        baseline = statistics.median(vals[:-1])
        if baseline <= 0:
            continue
        if direction == "worse" and vals[-1] > factor * baseline:
            failures.append(
                f"{name}: {vals[-1]:.0f} vs median {baseline:.0f} "
                f"({vals[-1] / baseline:.2f}x > {factor:.2f}x)")
        elif direction == "better" and vals[-1] < baseline / factor:
            failures.append(
                f"{name}: {vals[-1]:.3f} vs median {baseline:.3f} "
                f"({vals[-1] / baseline:.2f}x < 1/{factor:.2f}x — "
                f"larger-is-better unit)")
    return failures


def enforce(path: str | None = None,
            factor: float = REGRESSION_FACTOR) -> None:
    """``check`` + loud failure: prints every regression and exits 1."""
    failures = check(path, factor)
    if failures:
        print(f"PERF REGRESSION ({len(failures)} metric"
              f"{'s' if len(failures) != 1 else ''} > "
              f"{(factor - 1) * 100:.0f}% over baseline):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any >20%% latency regression")
    ap.add_argument("--path", default=None)
    args = ap.parse_args()
    if args.check:
        enforce(args.path)
        print("trajectory: no regressions")
        return
    rows = load(args.path)
    for row in rows:
        print(f"{row.get('ts', 0):.0f} {row.get('git_rev', '?'):>8s} "
              f"{row['name']:40s} {row['value']:12.1f} "
              f"{row.get('unit', '')} derived={row.get('derived')}")
    print(f"# {len(rows)} rows", file=sys.stderr)


if __name__ == "__main__":
    main()
