"""Quantized tiered store vs fp32 payload: recall parity, bytes, latency.

For each corpus size: fit one IRLI index, then serve the SAME queries
through the compact pipeline with four vector payloads —

  fp32        raw base array (the pre-store serving path; baseline)
  int8+exact  int8 block-scaled codes, fp32 exact tier for the k' refine
  int8        int8 codes, on-the-fly dequant refine (the deep1b deployment)
  bf16        bf16 codes, dequant refine

— and report end-to-end recall10@10 against true neighbors, the fraction
of queries whose top-k id set matches the fp32 path exactly, resident
payload bytes (codes+scales vs fp32), and per-query rerank latency.

Emits artifacts/BENCH_store.json with every row (CI smoke runs
``--toy``); also registered in benchmarks/run.py.
"""
import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.index import IRLIIndex, IRLIConfig
from repro.core.search_api import SearchParams
from repro.data.synthetic import clustered_ann
from repro.store import encode

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def _recall_of_ids(ids, gt):
    ids, gt = np.asarray(ids), np.asarray(gt)
    k = min(ids.shape[1], gt.shape[1])
    return float(np.mean([
        len(set(r[r >= 0]) & set(g[:k])) / k for r, g in zip(ids, gt)]))


def _id_set_match(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.mean([set(r[r >= 0]) == set(s[s >= 0])
                          for r, s in zip(a, b)]))


def run(csv=True, toy=False):
    sizes = ((1000, 32),) if toy else ((2000, 64), (8000, 128))
    n_queries = 100 if toy else 200
    rows, records = [], []
    for L, B_ in sizes:
        data = clustered_ann(n_base=L, n_queries=n_queries, d=16,
                             n_clusters=L // 20, seed=0)
        cfg = IRLIConfig(d=16, n_labels=L, n_buckets=B_, n_reps=4,
                         d_hidden=64, K=8, rounds=1 if toy else 2,
                         epochs_per_round=2 if toy else 3,
                         batch_size=512, lr=2e-3, seed=1)
        idx = IRLIIndex(cfg)
        idx.fit(data.train_queries, data.train_gt, label_vecs=data.base)
        queries = jnp.asarray(data.queries)
        base = np.asarray(data.base, np.float32)

        sp = SearchParams(mode="compact", m=4, tau=1, k=10, topC=1024)
        payloads = {
            "fp32": (jnp.asarray(base), sp),
            "int8+exact": (encode(base, "int8", 16, keep_exact=True),
                           sp.replace(store_dtype="int8", refine_k=64)),
            "int8": (encode(base, "int8", 16),
                     sp.replace(store_dtype="int8", refine_k=64)),
            "bf16": (encode(base, "bf16"),
                     sp.replace(store_dtype="bf16", refine_k=64)),
        }
        fp32_ids = None
        for tag, (payload, p) in payloads.items():
            res = idx.search(queries, payload, p)
            res.ids.block_until_ready()
            t0 = time.time()
            for _ in range(3):
                idx.search(queries, payload, p).ids.block_until_ready()
            us = (time.time() - t0) / (3 * n_queries) * 1e6
            if fp32_ids is None:
                fp32_ids = res.ids
            nbytes = (payload.nbytes() if hasattr(payload, "nbytes")
                      and callable(payload.nbytes) else L * 16 * 4)
            rec = {
                "corpus": L, "payload": tag,
                "recall10": round(_recall_of_ids(res.ids, data.gt), 4),
                "match_fp32": round(_id_set_match(fp32_ids, res.ids), 4),
                "payload_bytes": int(nbytes),
                "fp32_bytes": L * 16 * 4,
                "us_per_query": round(us, 1),
            }
            records.append(rec)
            rows.append((
                f"store/L={L}_{tag}", us,
                f"recall={rec['recall10']:.3f};match_fp32="
                f"{rec['match_fp32']:.2f};bytes={rec['payload_bytes']}"))

    os.makedirs(ART, exist_ok=True)
    out = os.path.join(ART, "BENCH_store.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=1)

    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")

    # smoke gates (CI runs --toy): the exact-tier deployment must match
    # fp32 results (refine is exact), the dequant-refine deployments may
    # trade a little recall for the 3x+ payload saving — bounded here
    by = {(r["corpus"], r["payload"]): r for r in records}
    for (L, tag), r in by.items():
        if tag == "int8+exact":
            assert r["match_fp32"] >= 0.95, r
        if tag.startswith("int8"):
            assert r["payload_bytes"] * 3 < r["fp32_bytes"], r
        base_rec = by[(L, "fp32")]["recall10"]
        slack = 0.002 if tag == "int8+exact" else 0.05
        assert r["recall10"] >= base_rec - slack, (r, base_rec)
    if not toy:     # --toy shapes would pollute the longitudinal baseline
        from benchmarks import trajectory
        trajectory.record("store", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--toy", action="store_true",
                    help="small corpus for the CI smoke step")
    args = ap.parse_args()
    run(toy=args.toy)
