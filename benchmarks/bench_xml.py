"""Paper Tables 1-2: extreme multi-label classification — P@1/3/5 and
per-query inference time, IRLI vs a brute-force dense scorer (the
'full softmax' reference) on synthetic Zipf-distributed multi-label data.

The paper's headline: comparable-or-better precision at ~5x faster
inference; here the speed proxy is candidates-scored per query
(m*R*bucket vs L) plus measured wall time on CPU.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as Q
from repro.core.index import IRLIIndex, IRLIConfig
from repro.data.synthetic import zipf_xml


def run(csv=True):
    data = zipf_xml(n_train=6000, n_test=500, d=24, n_labels=2000,
                    labels_per_point=3, seed=0)
    k = max(len(y) for y in data.y_train)
    ids = np.zeros((len(data.y_train), k), np.int32)
    msk = np.zeros((len(data.y_train), k), np.float32)
    for i, y in enumerate(data.y_train):
        ids[i, :len(y)] = y
        msk[i, :len(y)] = 1
    gt = np.zeros((len(data.y_test), 3), np.int32)
    for i, y in enumerate(data.y_test):
        gt[i, :len(y[:3])] = y[:3]
    gt = jnp.asarray(gt)

    rows = []
    cfg = IRLIConfig(d=24, n_labels=2000, n_buckets=256, n_reps=8,
                     d_hidden=160, K=10, rounds=4, epochs_per_round=4,
                     batch_size=512, lr=2e-3, seed=1)
    idx = IRLIIndex(cfg)
    idx.fit(data.x_train, ids, msk)

    for m in (5, 10):
        t0 = time.time()
        mask, freq, ncand = idx.query(data.x_test, m=m, tau=1)
        prec = Q.precision_at(mask, freq, None, None, gt)
        us = (time.time() - t0) / 500 * 1e6
        rows.append((f"xml/irli_m={m}", us,
                     f"P@1={float(prec['P@1']):.3f};"
                     f"P@3={float(prec['P@3']):.3f};"
                     f"P@5={float(prec['P@5']):.3f};"
                     f"cand={float(ncand.mean()):.0f}"))

    # dense brute-force baseline: score ALL labels with a one-vs-all linear
    # model trained on the same data (the "full softmax" reference)
    X = jnp.asarray(data.x_train)
    Yids, Ymask = jnp.asarray(ids), jnp.asarray(msk)
    W = jnp.zeros((24, 2000))

    @jax.jit
    def train_step(W, x, yid, ym):
        def loss(W):
            logits = x @ W
            onehot = jax.nn.one_hot(yid, 2000) * ym[..., None]
            t = jnp.clip(onehot.sum(1), 0, 1)
            return jnp.mean(jnp.sum(
                jnp.maximum(logits, 0) - logits * t +
                jnp.log1p(jnp.exp(-jnp.abs(logits))), axis=-1))
        g = jax.grad(loss)(W)
        return W - 0.5 * g
    for ep in range(30):
        W = train_step(W, X, Yids, Ymask)
    t0 = time.time()
    logits = jnp.asarray(data.x_test) @ W            # scores ALL 2000 labels
    _, top = jax.lax.top_k(logits, 5)
    jax.block_until_ready(top)
    us = (time.time() - t0) / 500 * 1e6
    hit1 = (top[:, :1, None] == gt[:, None, :]).any(-1).any(-1)
    hit3 = (top[:, :3, None] == gt[:, None, :]).any(-1)
    rows.append(("xml/dense_linear", us,
                 f"P@1={float(hit1.mean()):.3f};"
                 f"P@3={float(hit3.any(-1).mean()):.3f};cand=2000"))

    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    from benchmarks import trajectory
    trajectory.record("xml", rows)
    return rows


if __name__ == "__main__":
    run()
