"""Paper Table 3: std-dev of bucket load vs K (power-of-K-choices).

Reproduces the claim: random 2-universal hashing ~ binomial load noise;
K-choice re-partitioning with small K is WORSE than random (K=5 in the
paper), and load-std decreases monotonically as K grows.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.index import IRLIIndex, IRLIConfig
from repro.core.partition import load_std
from repro.data.synthetic import clustered_ann


def run(csv=True):
    data = clustered_ann(n_base=8000, n_queries=100, d=16, n_clusters=400,
                         seed=0)
    rows = []
    # binomial random reference
    rng = np.random.default_rng(0)
    ra = np.stack([np.bincount(rng.integers(0, 256, 8000), minlength=256)
                   for _ in range(4)])
    rand_std = float(np.mean(np.std(ra, axis=1)))
    rows.append(("load_balance/random", 0.0, rand_std))

    for K in (1, 5, 10, 25):
        t0 = time.time()
        cfg = IRLIConfig(d=16, n_labels=8000, n_buckets=256, n_reps=4,
                         d_hidden=128, K=K, rounds=3, epochs_per_round=4,
                         batch_size=512, lr=2e-3, seed=1)
        idx = IRLIIndex(cfg)
        stats = idx.fit(data.train_queries, data.train_gt,
                        label_vecs=data.base)
        rows.append((f"load_balance/K={K}", (time.time() - t0) * 1e6,
                     stats.load_std[-1]))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived:.2f}")
    from benchmarks import trajectory
    trajectory.record("load_balance", rows)
    return rows


if __name__ == "__main__":
    run()
