"""Benchmark harness entrypoint — one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>]

Prints ``name,us_per_call,derived`` CSV rows; every bench also appends its
rows to ``artifacts/TRAJECTORY.jsonl`` (benchmarks/trajectory.py), and the
sweep ends with a >20% latency-regression gate over that history (opt out
with ``--no-check``). The roofline table (`python -m benchmarks.roofline`)
reads the dry-run artifacts instead.
"""
import argparse
import sys
import time

BENCHES = [
    ("load_balance", "benchmarks.bench_load_balance", "paper Table 3"),
    ("recall_candidates", "benchmarks.bench_recall_candidates", "paper Fig 3"),
    ("compact_vs_dense", "benchmarks.bench_compact_vs_dense",
     "pipeline recall parity + memory crossover"),
    ("store", "benchmarks.bench_store",
     "quantized tiered store: recall parity + bytes + rerank latency"),
    ("fit", "benchmarks.bench_fit",
     "scan-compiled fit rounds vs host loop + affinity memory"),
    ("iterations", "benchmarks.bench_iterations", "paper Fig 4 / Table 4"),
    ("xml", "benchmarks.bench_xml", "paper Tables 1-2"),
    ("distributed", "benchmarks.bench_distributed", "paper Figs 5-6"),
    ("streaming", "benchmarks.bench_streaming",
     "mutable index: insert/delete/compact throughput + recall"),
    ("online", "benchmarks.bench_online",
     "online refit under drift: recall-gap recovery + swap-pause p99"),
    ("kernel_roofline", "benchmarks.bench_kernel_roofline",
     "scorer_logits + member_gather + freq_topc + quant_rerank "
     "achieved-vs-peak bandwidth"),
    ("megakernel", "benchmarks.bench_megakernel",
     "fused single-dispatch query path vs staged compact pipeline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the TRAJECTORY.jsonl >20%% regression gate "
                         "(e.g. deliberately slower debug builds)")
    args = ap.parse_args()

    import importlib
    from benchmarks import trajectory
    print("name,us_per_call,derived")
    failures = 0
    for name, mod, what in BENCHES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            importlib.import_module(mod).run(csv=True)
            print(f"# {name} ({what}) done in {time.time()-t0:.0f}s",
                  file=sys.stderr)
        except Exception as e:
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
    if failures:
        sys.exit(1)
    if not args.no_check:
        # every bench above appended its rows to artifacts/TRAJECTORY.jsonl;
        # fail the sweep loudly on any >20% latency regression vs history
        trajectory.enforce()
        print("# trajectory: no regressions", file=sys.stderr)
    sys.exit(0)


if __name__ == '__main__':
    main()
