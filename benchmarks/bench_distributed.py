"""Paper Figs. 5-6: P-way sharded KNN — recall vs per-query latency as the
probe count m grows, on a corpus sharded across P disjoint "nodes" with
R_local reps each (the paper's 8x V100 layout, scaled to CPU).

Each shard builds its own IRLI index over its slice; queries fan out to all
shards; candidates merge by true-distance top-k (exactly §5.3). Also
reports the FAISS-analogue brute-force scan as the recall ceiling."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as Q
from repro.core.distributed import shard_search_local, shard_corpus
from repro.core.index import IRLIIndex, IRLIConfig
from repro.core.search_api import SearchParams
from repro.data.synthetic import clustered_ann

P_SHARDS = 4


def run(csv=True):
    data = clustered_ann(n_base=8192, n_queries=200, d=16, n_clusters=400,
                         seed=0)
    gt = data.gt
    rows = []
    shards = shard_corpus(data.base, P_SHARDS)       # [P, L/P, d]
    L_loc = shards.shape[1]

    # per-shard indexes (paper: P nodes x R_local models)
    indexes = []
    for s in range(P_SHARDS):
        base_s = np.asarray(shards[s])
        tg = np.argsort(
            -(base_s @ base_s.T), axis=1)[:, :10].astype(np.int32)
        cfg = IRLIConfig(d=16, n_labels=L_loc, n_buckets=64, n_reps=6,
                         d_hidden=96, K=16, rounds=4, epochs_per_round=4,
                         batch_size=512, lr=2e-3, seed=10 + s)
        idx = IRLIIndex(cfg)
        idx.fit(base_s, tg, label_vecs=base_s)
        indexes.append(idx)

    queries = jnp.asarray(data.queries)
    for m in (1, 2, 4, 8):
        t0 = time.time()
        all_ids, all_scores = [], []
        sp = SearchParams(m=m, tau=1, k=10, topC=2048)
        for s, idx in enumerate(indexes):
            res = shard_search_local(
                idx.params, idx.index.members, shards[s], queries,
                sp, q_chunk=200)
            all_ids.append(np.where(np.asarray(res.ids) >= 0,
                                    np.asarray(res.ids) + s * L_loc, -1))
            all_scores.append(np.asarray(res.scores))
        sc = np.concatenate(all_scores, 1)
        gl = np.concatenate(all_ids, 1)
        order = np.argsort(-sc, 1)[:, :10]
        merged = np.take_along_axis(gl, order, 1)
        us = (time.time() - t0) / len(queries) * 1e6
        rec = np.mean([len(set(m_) & set(g)) / 10
                       for m_, g in zip(merged, gt)])
        rows.append((f"distributed/P={P_SHARDS}_m={m}", us,
                     f"recall={rec:.3f}"))

    # brute force ceiling
    t0 = time.time()
    sim = data.queries @ data.base.T
    top = np.argsort(-sim, 1)[:, :10]
    us = (time.time() - t0) / len(queries) * 1e6
    rec = np.mean([len(set(t) & set(g)) / 10 for t, g in zip(top, gt)])
    rows.append(("distributed/bruteforce", us, f"recall={rec:.3f}"))

    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    from benchmarks import trajectory
    trajectory.record("distributed", rows)
    return rows


if __name__ == "__main__":
    run()
