"""Fit-path benchmark: scan-compiled FitEngine rounds vs the seed host loop.

Three measurements, all written to artifacts/BENCH_fit.json (and printed as
the harness CSV):

  1. wall-clock per train/re-partition round — the seed-style host loop
     (one jitted step per batch + dense [R, L, B] affinity + per-rep Python
     k-choice) vs the engine's single compiled round;
  2. peak affinity-path intermediate bytes, measured by walking the traced
     jaxpr of each re-partition path (dense materializes [R, L, B]; the
     streaming reducer's largest block is [R, chunk, B] / the [R, L, K]
     carry);
  3. the same engine round on 1 vs 4 fake host devices on a
     ("data", "rep") mesh (subprocesses, since the device count is fixed at
     jax init) — scaling sanity on CPU, the real win is on a TPU slice.

    PYTHONPATH=src python -m benchmarks.bench_fit [--toy]
"""
import argparse
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition as PT
from repro.core import repartition as RP
from repro.core.index import IRLIConfig
from repro.core.network import ScorerConfig, scorer_init, scorer_loss
from repro.data.synthetic import clustered_ann
from repro.fit import FitData, FitEngine, FitState, affinity_topk_ann
from repro.optim.optimizers import make_optimizer

from repro.analysis.jaxpr import peak_intermediate_bytes

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


# ------------------------------------------------------ host-loop baseline --
def make_hostloop_round(cfg, scfg, opt):
    """The seed IRLIIndex.fit inner loop, verbatim semantics: ONE jitted
    per-batch step cached across rounds (as the seed cached
    ``self._train_step``) with a host sync each batch, dense [R, L, B]
    affinity, Python loop over reps for k-choice."""

    @jax.jit
    def train_step(params, opt_state, xb, ib, mb, assign):
        targets = PT.bucket_targets(assign, ib, mb, cfg.n_buckets)
        loss, grads = jax.value_and_grad(
            lambda p: scorer_loss(p, scfg, xb, targets))(params)
        params, opt_state, _ = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    def hostloop_round(params, opt_state, assign, x, ids, mask, lv, key):
        n, bs = x.shape[0], min(cfg.batch_size, x.shape[0])
        for ep in range(cfg.epochs_per_round):
            key, ke = jax.random.split(key)
            perm = jax.random.permutation(ke, n)
            for s in range(0, n - bs + 1, bs):
                sel = perm[s:s + bs]
                params, opt_state, loss = train_step(
                    params, opt_state, x[sel], ids[sel], mask[sel], assign)
                float(loss)                   # the seed's per-batch sync
        aff = RP.affinity_ann(params, lv, cfg.loss)
        key, kr = jax.random.split(key)
        vals, idxs = jax.lax.top_k(aff, cfg.K)
        outs = [RP.kchoice_exact(idxs[r], cfg.n_buckets,
                                 jax.random.fold_in(kr, r))
                for r in range(cfg.n_reps)]
        return params, opt_state, jnp.stack(outs), key

    return hostloop_round


def _time_rounds(fn, n_rounds):
    fn()                                      # warmup / compile
    t0 = time.time()
    for _ in range(n_rounds):
        fn()
    return (time.time() - t0) / n_rounds


_DEVICE_SCRIPT = textwrap.dedent("""
    import os, sys, time, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import jax
    from repro.core.index import IRLIConfig, IRLIIndex
    from repro.data.synthetic import clustered_ann
    from repro.launch.mesh import make_fit_mesh

    n, rounds = %d, %d
    data = clustered_ann(n_base=n, n_queries=16, d=16, n_clusters=n // 20,
                         k_gt=10, k_train=20, seed=0)
    cfg = IRLIConfig(d=16, n_labels=n, n_buckets=64, n_reps=4, d_hidden=64,
                     K=8, rounds=rounds, epochs_per_round=2, batch_size=512,
                     lr=2e-3, affinity_chunk=512, seed=1)
    mesh = make_fit_mesh(rep_axis=2) if len(jax.devices()) > 1 else None
    idx = IRLIIndex(cfg)
    t0 = time.time()
    stats = idx.fit(data.train_queries, data.train_gt, label_vecs=data.base,
                    mesh=mesh)
    per_round = (time.time() - t0) / len(stats.round_idx)
    print(json.dumps({"devices": len(jax.devices()),
                      "s_per_round": per_round,
                      "loss": stats.train_loss}))
""")


def run(csv=True, toy=False):
    n = 1024 if toy else 4096
    rounds = 1 if toy else 2
    cfg = IRLIConfig(d=16, n_labels=n, n_buckets=64, n_reps=4, d_hidden=64,
                     K=8, rounds=rounds, epochs_per_round=2, batch_size=512,
                     lr=2e-3, affinity_chunk=512, seed=1)
    scfg = ScorerConfig(d_in=cfg.d, d_hidden=cfg.d_hidden,
                        n_buckets=cfg.n_buckets, n_reps=cfg.n_reps,
                        loss=cfg.loss)
    data = clustered_ann(n_base=n, n_queries=16, d=16, n_clusters=n // 20,
                         k_gt=10, k_train=20, seed=0)
    x = jnp.asarray(data.train_queries)
    ids = jnp.asarray(data.train_gt, jnp.int32)
    mask = jnp.ones(ids.shape, jnp.float32)
    lv = jnp.asarray(data.base)
    params = scorer_init(jax.random.PRNGKey(0), scfg)

    rows, rec = [], {}

    # --- host loop (seed behavior) --------------------------------------
    opt_host = make_optimizer("adamw", lr=cfg.lr, weight_decay=0.0,
                              master_fp32=False)
    hstate = {"params": jax.tree.map(jnp.copy, params)}
    hstate["opt"] = opt_host.init(hstate["params"])
    hstate["assign"] = PT.hash_init(n, cfg.n_buckets, cfg.n_reps, cfg.seed)
    hstate["key"] = jax.random.PRNGKey(cfg.seed)

    hostloop_round = make_hostloop_round(cfg, scfg, opt_host)

    def host_round():
        hstate["params"], hstate["opt"], hstate["assign"], hstate["key"] = \
            hostloop_round(hstate["params"], hstate["opt"], hstate["assign"],
                           x, ids, mask, lv, hstate["key"])

    host_s = _time_rounds(host_round, rounds)

    # --- scan-compiled engine round -------------------------------------
    eng = FitEngine(cfg, scfg)
    fdata = FitData.build(x, ids, label_vecs=lv, n_labels=n,
                          chunk=cfg.affinity_chunk)
    round_fn = eng.make_fit_round(fdata)
    box = {"state": FitState.create(
        jax.tree.map(jnp.copy, params), eng.opt.init(params),
        PT.hash_init(n, cfg.n_buckets, cfg.n_reps, cfg.seed),
        jax.random.PRNGKey(cfg.seed)), "rnd": 0}

    def engine_round():
        bidx, bw = eng.round_batches(n, cfg.seed, box["rnd"])
        box["state"], met = round_fn(box["state"], bidx, bw)
        jax.block_until_ready(met["loss"])
        box["rnd"] += 1

    engine_s = _time_rounds(engine_round, rounds)

    rows.append(("fit/host_loop_round", host_s * 1e6,
                 f"n={n};R={cfg.n_reps};B={cfg.n_buckets}"))
    rows.append(("fit/engine_round", engine_s * 1e6,
                 f"speedup={host_s / engine_s:.2f}x"))
    rec.update(n=n, host_s_per_round=host_s, engine_s_per_round=engine_s,
               speedup=host_s / engine_s)

    # --- peak affinity bytes (jaxpr walk) -------------------------------
    dense_fn = lambda p: RP.repartition(RP.affinity_ann(p, lv, cfg.loss),
                                        cfg.K, cfg.n_buckets, "exact",
                                        jax.random.PRNGKey(0))
    stream_fn = lambda p: RP.repartition_topk(
        *affinity_topk_ann(p, lv, cfg.K, cfg.loss, cfg.affinity_chunk),
        cfg.n_buckets, "exact",
        RP.rep_fold_keys(jax.random.PRNGKey(0), jnp.arange(cfg.n_reps)))
    dense_b = peak_intermediate_bytes(dense_fn, params)
    stream_b = peak_intermediate_bytes(stream_fn, params)
    rows.append(("fit/affinity_peak_dense_bytes", dense_b,
                 f"[R,L,B]={cfg.n_reps * n * cfg.n_buckets * 4}"))
    rows.append(("fit/affinity_peak_stream_bytes", stream_b,
                 f"ratio={dense_b / max(stream_b, 1):.1f}x"))
    rec.update(affinity_peak_dense_bytes=dense_b,
               affinity_peak_stream_bytes=stream_b)

    # --- 1 vs 4 fake devices (subprocess; device count fixed at init) ----
    if not toy:
        for ndev in (1, 4):
            script = _DEVICE_SCRIPT % (ndev, n, rounds)
            r = subprocess.run([sys.executable, "-c", script],
                               capture_output=True, text=True, timeout=1200)
            if r.returncode != 0:
                print(f"# devices={ndev} run failed: {r.stderr[-500:]}",
                      file=sys.stderr)
                continue
            out = json.loads(r.stdout.strip().splitlines()[-1])
            rows.append((f"fit/engine_round_devices={ndev}",
                         out["s_per_round"] * 1e6,
                         f"loss_end={out['loss'][-1]:.3f}"))
            rec[f"s_per_round_devices{ndev}"] = out["s_per_round"]

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "BENCH_fit.json"), "w") as f:
        json.dump(rec, f, indent=1)

    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    if not toy:     # --toy shapes would pollute the longitudinal baseline
        from benchmarks import trajectory
        trajectory.record("fit", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--toy", action="store_true",
                    help="CI smoke: small shapes, no subprocess device runs")
    run(toy=ap.parse_args().toy)
