"""Streaming updates demo: fit an IRLI index, then grow and shrink it ONLINE
— no retraining — through the MutableIRLIIndex and the serving micro-batcher,
all via the typed search API (SearchParams in, SearchResult out).

    PYTHONPATH=src python examples/streaming_updates.py
"""
import numpy as np

from repro.core.index import IRLIIndex, IRLIConfig
from repro.core.search_api import SearchParams
from repro.data.synthetic import clustered_ann, _topk_l2
from repro.serve.server import IRLIServer
from repro.stream import MutableIRLIIndex


def main():
    n_init, n_new, d = 4000, 800, 16
    print(f"generating {n_init}+{n_new} synthetic vectors ...")
    data = clustered_ann(n_base=n_init + n_new, n_queries=100, d=d,
                         n_clusters=100, seed=0)
    base, new_vecs = data.base[:n_init], data.base[n_init:]

    cfg = IRLIConfig(d=d, n_labels=n_init, n_buckets=64, n_reps=4,
                     d_hidden=64, K=8, rounds=2, epochs_per_round=3,
                     batch_size=512, lr=2e-3, seed=1)
    print("fitting the frozen index on the initial corpus ...")
    idx = IRLIIndex(cfg)
    idx.fit(base, _topk_l2(base, base, 10, "angular"), label_vecs=base)

    sp = SearchParams(m=8, tau=1, k=10)
    mut = MutableIRLIIndex(idx, base)
    print(f"insert {n_new} new items (power-of-{cfg.K} online placement) ...")
    ids = mut.insert(new_vecs)
    res = mut.search(new_vecs, sp)
    got = np.asarray(res.ids)
    rec = np.mean([ids[i] in got[i] for i in range(len(ids))])
    print(f"  inserted items immediately retrievable: recall@10 = {rec:.3f} "
          f"(epoch={res.epoch})")

    dead = np.arange(0, 200)
    print(f"delete {len(dead)} originals (tombstoned) ...")
    mut.delete(dead)
    res = mut.search(data.queries, sp)
    assert not np.isin(np.asarray(res.ids), dead).any()
    print("  deleted ids never appear in results")

    print("compact (delta + tombstones -> rebuilt member matrix) ...")
    pre = mut.search(data.queries, sp)
    mut.compact()
    post = mut.search(data.queries, sp)
    same = bool(np.array_equal(np.asarray(pre.ids), np.asarray(post.ids)))
    print(f"  query results preserved exactly: {same}  "
          f"(epoch={mut.epoch}, live={mut.n_live}/{mut.n_total})")

    print("serving: queries + mutations through one admission queue, with a "
          "per-request params override ...")
    server = IRLIServer(mut, params=sp, max_batch=64, max_wait_ms=2.0)
    futs = [server.submit(q) for q in data.queries[:24]]
    # a few requests probe wider — they form their own micro-batch group
    futs += [server.submit(q, sp.replace(m=16)) for q in data.queries[24:32]]
    more = server.insert(np.asarray(data.queries[:4]))   # mutation barrier
    _ = [f.result(timeout=120) for f in futs]
    print(f"  served {server.stats['requests']} queries in "
          f"{server.stats['param_groups']} param groups / "
          f"{server.stats['batches']} batches; inserted ids "
          f"{list(map(int, more.result(timeout=120)))}; "
          f"epoch={server.stats['epoch']}; cache={server.stats['cache']}")
    server.close()


if __name__ == "__main__":
    main()
