"""End-to-end serving driver (the paper's kind: §5.3 distributed KNN +
batched online queries). Builds a P-way sharded IRLI index implementing the
``Searcher`` protocol (so the server treats it like any other backend),
serves batched requests through the micro-batching server, reports latency
percentiles and recall — the CPU-scale analogue of the paper's 100M-point
deployment.

    PYTHONPATH=src python examples/distributed_knn.py [--shards 4]
"""
import argparse
import time

import numpy as np

from repro.core.distributed import shard_corpus, shard_search_local
from repro.core.index import IRLIIndex, IRLIConfig
from repro.core.search_api import SearchParams, SearchResult, Searcher
from repro.data.synthetic import clustered_ann
from repro.serve.server import IRLIServer


class ShardedIndex:
    """P per-shard IRLI indexes + true-distance merge (paper Fig. 5/6).
    Implements the Searcher protocol: search(queries, params) ->
    SearchResult with globally-offset ids."""

    def __init__(self, base, n_shards, seed=0):
        self.shards = shard_corpus(base, n_shards)
        self.L_loc = self.shards.shape[1]
        self.indexes = []
        for s in range(n_shards):
            bs = np.asarray(self.shards[s])
            gt = np.argsort(-(bs @ bs.T), axis=1)[:, :10].astype(np.int32)
            cfg = IRLIConfig(d=bs.shape[1], n_labels=self.L_loc, n_buckets=64,
                             n_reps=4, d_hidden=96, K=10, rounds=3,
                             epochs_per_round=3, batch_size=512, lr=2e-3,
                             seed=seed + s)
            idx = IRLIIndex(cfg)
            idx.fit(bs, gt, label_vecs=bs)
            self.indexes.append(idx)

    def search(self, queries, params: SearchParams) -> SearchResult:
        all_ids, all_sc, n_cand = [], [], 0
        for s, idx in enumerate(self.indexes):
            res = shard_search_local(
                idx.params, idx.index.members, self.shards[s], queries,
                params, q_chunk=max(1, len(queries)))
            ids = np.asarray(res.ids)
            all_ids.append(np.where(ids >= 0, ids + s * self.L_loc, -1))
            all_sc.append(np.asarray(res.scores))
            n_cand = n_cand + np.asarray(res.n_candidates)
        sc = np.concatenate(all_sc, 1)
        gl = np.concatenate(all_ids, 1)
        order = np.argsort(-sc, 1)[:, :params.k]
        return SearchResult(ids=np.take_along_axis(gl, order, 1),
                            scores=np.take_along_axis(sc, order, 1),
                            n_candidates=n_cand, mode="compact")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--requests", type=int, default=256)
    args = ap.parse_args()

    data = clustered_ann(n_base=8192, n_queries=args.requests, d=16,
                         n_clusters=400, seed=0)
    print(f"building {args.shards}-way sharded index over 8192 vectors ...")
    t0 = time.time()
    sharded = ShardedIndex(data.base, args.shards)
    assert isinstance(sharded, Searcher)
    print(f"  built in {time.time()-t0:.0f}s")

    # offline recall check through the typed interface
    sp = SearchParams(m=4, tau=1, k=10)
    res = sharded.search(data.queries, sp)
    rec = np.mean([len(set(i) & set(g)) / 10
                   for i, g in zip(res.ids, data.gt)])
    print(f"offline recall10@10 = {rec:.3f}")

    # online serving through the micro-batching server: ShardedIndex is a
    # one-arg Searcher, so the server binds it like any other backend
    server = IRLIServer(sharded, params=sp, max_batch=64, max_wait_ms=2.0)
    lat = []
    futs = []
    t0 = time.time()
    for i in range(args.requests):
        t = time.time()
        futs.append((t, server.submit(data.queries[i])))
    for t, f in futs:
        f.result(timeout=600)
        lat.append((time.time() - t) * 1000)
    total = time.time() - t0
    lat = np.sort(np.asarray(lat))
    print(f"served {args.requests} requests in {total:.2f}s "
          f"({args.requests/total:.0f} qps)")
    print(f"latency ms: p50={lat[len(lat)//2]:.1f} "
          f"p95={lat[int(len(lat)*.95)]:.1f} p99={lat[int(len(lat)*.99)]:.1f}")
    print(f"server stats: {server.stats}")
    server.close()


if __name__ == "__main__":
    main()
