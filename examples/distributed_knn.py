"""End-to-end serving driver (the paper's kind: §5.3 distributed KNN +
batched online queries). Builds a P-way sharded IRLI index, serves batched
requests through the micro-batching server, reports latency percentiles and
recall — the CPU-scale analogue of the paper's 100M-point deployment.

    PYTHONPATH=src python examples/distributed_knn.py [--shards 4]
"""
import argparse
import time

import numpy as np

from repro.core.distributed import shard_corpus, shard_search_local
from repro.core.index import IRLIIndex, IRLIConfig
from repro.data.synthetic import clustered_ann
from repro.serve.server import IRLIServer


class ShardedIndex:
    """P per-shard IRLI indexes + true-distance merge (paper Fig. 5/6)."""

    def __init__(self, base, n_shards, seed=0):
        self.shards = shard_corpus(base, n_shards)
        self.L_loc = self.shards.shape[1]
        self.indexes = []
        for s in range(n_shards):
            bs = np.asarray(self.shards[s])
            gt = np.argsort(-(bs @ bs.T), axis=1)[:, :10].astype(np.int32)
            cfg = IRLIConfig(d=bs.shape[1], n_labels=self.L_loc, n_buckets=64,
                             n_reps=4, d_hidden=96, K=10, rounds=3,
                             epochs_per_round=3, batch_size=512, lr=2e-3,
                             seed=seed + s)
            idx = IRLIIndex(cfg)
            idx.fit(bs, gt, label_vecs=bs)
            self.indexes.append(idx)

    def search(self, queries, base=None, m=4, tau=1, k=10, metric="angular"):
        all_ids, all_sc = [], []
        for s, idx in enumerate(self.indexes):
            ids, sc = shard_search_local(
                idx.params, idx.index.members, self.shards[s], queries,
                m=m, tau=tau, k=k, topC=1024, q_chunk=max(1, len(queries)))
            all_ids.append(np.where(np.asarray(ids) >= 0,
                                    np.asarray(ids) + s * self.L_loc, -1))
            all_sc.append(np.asarray(sc))
        sc = np.concatenate(all_sc, 1)
        gl = np.concatenate(all_ids, 1)
        order = np.argsort(-sc, 1)[:, :k]
        return np.take_along_axis(gl, order, 1), None

    def query(self, queries, m=4, tau=1):  # server fallback path
        ids, _ = self.search(queries, m=m, tau=tau)
        return ids, None, None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--requests", type=int, default=256)
    args = ap.parse_args()

    data = clustered_ann(n_base=8192, n_queries=args.requests, d=16,
                         n_clusters=400, seed=0)
    print(f"building {args.shards}-way sharded index over 8192 vectors ...")
    t0 = time.time()
    sharded = ShardedIndex(data.base, args.shards)
    print(f"  built in {time.time()-t0:.0f}s")

    # offline recall check
    ids, _ = sharded.search(data.queries, k=10)
    rec = np.mean([len(set(i) & set(g)) / 10 for i, g in zip(ids, data.gt)])
    print(f"offline recall10@10 = {rec:.3f}")

    # online serving through the micro-batching server
    server = IRLIServer(sharded, m=4, tau=1, k=10, base=data.base,
                        max_batch=64, max_wait_ms=2.0)
    lat = []
    futs = []
    t0 = time.time()
    for i in range(args.requests):
        t = time.time()
        futs.append((t, server.submit(data.queries[i])))
    for t, f in futs:
        f.result()
        lat.append((time.time() - t) * 1000)
    total = time.time() - t0
    lat = np.sort(np.asarray(lat))
    print(f"served {args.requests} requests in {total:.2f}s "
          f"({args.requests/total:.0f} qps)")
    print(f"latency ms: p50={lat[len(lat)//2]:.1f} "
          f"p95={lat[int(len(lat)*.95)]:.1f} p99={lat[int(len(lat)*.99)]:.1f}")
    print(f"server stats: {server.stats}")
    server.close()


if __name__ == "__main__":
    main()
