"""Quickstart: build an IRLI index on synthetic clustered vectors, query it,
print recall vs the brute-force ground truth. ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import query as Q
from repro.core.index import IRLIIndex, IRLIConfig
from repro.core.search_api import SearchParams
from repro.data.synthetic import clustered_ann


def main():
    print("generating 8k synthetic vectors + exact neighbors ...")
    data = clustered_ann(n_base=8000, n_queries=200, d=16, n_clusters=400,
                         seed=0)

    cfg = IRLIConfig(d=16, n_labels=8000, n_buckets=128, n_reps=8,
                     d_hidden=128, K=16, rounds=4, epochs_per_round=4,
                     batch_size=512, lr=2e-3, seed=1)
    print(f"fitting IRLI: B={cfg.n_buckets} buckets x R={cfg.n_reps} reps, "
          f"K={cfg.K}-choice load balancing ...")
    idx = IRLIIndex(cfg)
    stats = idx.fit(data.train_queries, data.train_gt, label_vecs=data.base,
                    verbose=True)

    for m in (1, 2, 4):
        mask, freq, ncand = idx.query(data.queries, m=m, tau=1)
        rec = float(Q.recall_at(mask, jnp.asarray(data.gt)))
        print(f"m={m}: recall10@10 = {rec:.3f} with "
              f"{float(ncand.mean()):.0f}/8000 candidates "
              f"({float(ncand.mean())/80:.1f}% of corpus)")

    res = idx.search(data.queries[:5], data.base, SearchParams(m=4, k=10))
    print(f"sample top-10 ids for first query (mode={res.mode}):",
          list(map(int, res.ids[0])))


if __name__ == "__main__":
    main()
