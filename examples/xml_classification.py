"""Extreme multi-label classification with IRLI (paper §5.1, Wiki-500K
scenario at synthetic scale): labels have NO vectors, so re-partitioning
uses Def. 1 affinity (sum of scorer outputs over a label's training points).

    PYTHONPATH=src python examples/xml_classification.py [--labels 2000]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import query as Q
from repro.core.index import IRLIIndex, IRLIConfig
from repro.data.synthetic import zipf_xml


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--labels", type=int, default=2000)
    ap.add_argument("--train", type=int, default=6000)
    args = ap.parse_args()

    data = zipf_xml(n_train=args.train, n_test=500, d=24,
                    n_labels=args.labels, labels_per_point=3, seed=0)
    print(f"XML data: {args.train} points, {args.labels} Zipf labels "
          f"(head label freq {int(data.label_freq.max())})")

    k = max(len(y) for y in data.y_train)
    ids = np.zeros((len(data.y_train), k), np.int32)
    msk = np.zeros((len(data.y_train), k), np.float32)
    for i, y in enumerate(data.y_train):
        ids[i, :len(y)] = y
        msk[i, :len(y)] = 1

    cfg = IRLIConfig(d=24, n_labels=args.labels, n_buckets=256, n_reps=8,
                     d_hidden=160, K=10, rounds=4, epochs_per_round=4,
                     batch_size=512, lr=2e-3, seed=1)
    idx = IRLIIndex(cfg)
    idx.fit(data.x_train, ids, msk, verbose=True)   # Def.1 affinity

    gt = np.zeros((len(data.y_test), 3), np.int32)
    for i, y in enumerate(data.y_test):
        gt[i, :len(y[:3])] = y[:3]

    for m in (5, 10):
        mask, freq, ncand = idx.query(data.x_test, m=m, tau=1)
        prec = Q.precision_at(mask, freq, None, None, jnp.asarray(gt))
        print(f"m={m}: " + " ".join(f"{k}={float(v):.3f}"
                                    for k, v in prec.items())
              + f"  candidates={float(ncand.mean()):.0f}/{args.labels}")


if __name__ == "__main__":
    main()
