"""End-to-end training driver: train the IRLI scorer stack (the paper's own
model — R feed-forward nets as one stacked module) for a few hundred steps
through the fault-tolerant Trainer, with periodic re-partitioning,
checkpointing and (optionally) a simulated crash + auto-resume.

    PYTHONPATH=src python examples/train_scorers_e2e.py --steps 200
    PYTHONPATH=src python examples/train_scorers_e2e.py --steps 200 --crash-at 120
    # then run again without --crash-at: resumes from the last checkpoint
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.network import ScorerConfig, scorer_init, scorer_loss
from repro.core import partition as PT, repartition as RP, query as Q
from repro.data.synthetic import clustered_ann
from repro.optim.optimizers import make_optimizer, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig, SimulatedFailure


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/irli_e2e_ckpt")
    ap.add_argument("--batch", type=int, default=512)
    args = ap.parse_args()

    # data: base vectors are the train queries (paper ANN mode)
    data = clustered_ann(n_base=8000, n_queries=200, d=32, n_clusters=400,
                         k_train=20, seed=0)
    x = jnp.asarray(data.train_queries)
    ids = jnp.asarray(data.train_gt)
    mask = jnp.ones(ids.shape, jnp.float32)

    scfg = ScorerConfig(d_in=32, d_hidden=512, n_buckets=256, n_reps=4)
    n_params = 4 * (32 * 512 + 512 * 256 + 512 + 256)
    print(f"scorer stack: R=4 x (32->512->256) = {n_params/1e6:.1f}M params")

    assign0 = PT.hash_init(8000, 256, 4, 0)
    opt = make_optimizer("adamw", lr=cosine_schedule(2e-3, 20, args.steps),
                         master_fp32=False)

    def init_state():
        params = scorer_init(jax.random.PRNGKey(0), scfg)
        return {"params": params, "opt": opt.init(params),
                "assign": assign0}

    def step_fn(state, batch):
        def loss(p):
            targets = PT.bucket_targets(state["assign"], batch["ids"],
                                        batch["mask"], 256)
            return scorer_loss(p, scfg, batch["x"], targets)
        l, g = jax.value_and_grad(loss)(state["params"])
        p2, o2, info = opt.update(state["params"], g, state["opt"])
        return {"params": p2, "opt": o2, "assign": state["assign"]}, \
            {"loss": l, **info}

    def batch_fn(step):
        k = jax.random.PRNGKey(777 + step)
        sel = jax.random.randint(k, (args.batch,), 0, 8000)
        return {"x": x[sel], "ids": ids[sel], "mask": mask[sel]}

    cfg = TrainerConfig(total_steps=args.steps, checkpoint_every=40,
                        fail_at_step=args.crash_at, log_every=20)
    tr = Trainer(cfg, step_fn, init_state, batch_fn, args.ckpt)
    if tr.resumed:
        print(f"RESUMED from checkpoint at step {tr.start_step - 1}")

    # alternating re-partition every 50 steps (Alg. 1), interleaved manually
    try:
        while tr.start_step < args.steps:
            seg_end = min(args.steps,
                          (tr.start_step // 50 + 1) * 50)
            tr.cfg = TrainerConfig(
                total_steps=seg_end, checkpoint_every=40,
                fail_at_step=args.crash_at, log_every=20)
            out = tr.run()
            tr.start_step = seg_end
            if seg_end < args.steps:
                aff = RP.affinity_ann(tr.state["params"],
                                      jnp.asarray(data.base), scfg.loss)
                new_assign = RP.repartition(aff, 10, 256, "exact",
                                            jax.random.PRNGKey(seg_end))
                moved = int(jnp.sum(new_assign != tr.state["assign"]))
                tr.state = dict(tr.state, assign=new_assign)
                lstd = float(PT.load_std(new_assign, 256))
                print(f"[repartition @ step {seg_end}] moved={moved} "
                      f"load_std={lstd:.2f}")
    except SimulatedFailure as e:
        print(f"CRASHED: {e} — run again without --crash-at to resume")
        return

    losses = [m["loss"] for m in tr.metrics_log]
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps; stragglers={tr.straggler_steps}")

    # evaluate the trained index
    index = PT.build_inverted_index(tr.state["assign"], 256, 2 * 8000 // 256)
    mask_q, _, ncand = Q.query_index(
        tr.state["params"], index, jnp.asarray(data.queries), m=8, tau=1,
        L=8000, loss_kind=scfg.loss)
    rec = float(Q.recall_at(mask_q, jnp.asarray(data.gt)))
    print(f"recall10@10 = {rec:.3f} at {float(ncand.mean()):.0f}/8000 "
          "candidates")
    print("(a short demo run; IRLIIndex.fit with full epochs reaches ~0.9 — "
          "see examples/quickstart.py. This driver demonstrates the "
          "fault-tolerant Trainer + checkpoint/resume + repartition loop.)")


if __name__ == "__main__":
    main()
